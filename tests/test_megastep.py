"""Megastep serving: K engine steps per host dispatch.

Contracts under test:
  * token exactness — ``megastep=1`` is bit-identical to the classic
    per-step loop, and any K > 1 generates the same tokens AND the same
    admission/completion step timing (run() never megasteps across an
    admission event), for both ring and recurrent cache families;
  * sync budget — at most ONE device->host transfer per megastep (the
    packed (B, 3+K) readback), guarded with ``jax.transfer_guard``;
  * dispatch accounting — ``host_dispatches`` shrinks relative to
    ``steps`` as the megastep width grows, and the megastep program is
    compiled once per (ModelAPI, config, K) cell;
  * policy feedback aggregation — folding K per-step ``Feedback``s
    through ``Policy.update`` equals one aggregated megastep update
    (``core.policies.fold_feedback`` over ``stack_feedbacks``), and a
    hint-seeded read-fraction forecast survives the fold un-drifted;
  * the staged duplex kernel variant is numerically identical to the
    per-page grid.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policies as policies_lib
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref
from repro.models import registry as R
from repro.serve import (EngineConfig, KVStoreTenant, ServeEngine,
                         reference_decode)
from repro.serve.engine import _fused_megastep_program


@pytest.fixture(scope="module")
def api():
    return R.build("smollm-135m", smoke=True)


@pytest.fixture(scope="module")
def params(api):
    return api.init(jax.random.PRNGKey(0))


def _cfg(**kw):
    base = dict(max_batch=3, cache_len=64, block_tokens=4, hbm_blocks=6,
                prefill_chunk=3, max_queue=8)
    base.update(kw)
    return EngineConfig(**base)


class TestMegastepExactness:
    @pytest.mark.parametrize("megastep", [1, 4, 8])
    def test_ring_matches_static_reference(self, api, params, megastep):
        """Acceptance: every megastep width generates token-for-token
        what the static reference batch produces, under staggered
        arrivals and slot recycling."""
        prompts = jax.random.randint(jax.random.PRNGKey(21), (5, 6), 0,
                                     api.cfg.vocab)
        ref = np.asarray(reference_decode(api, params, prompts, 10,
                                          cache_len=64))
        eng = ServeEngine(api, params, _cfg(megastep=megastep))
        rids = [eng.submit(np.asarray(prompts[i]), 10,
                           arrival_step=2 * i).rid for i in range(5)]
        outs = eng.run(max_steps=300)
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(outs[rid], ref[i])
        assert eng.paging_stats()["page_ins"] > 0

    @pytest.mark.parametrize("arch", ["rwkv6-7b", "zamba2-7b"])
    def test_recurrent_families_exact_at_k4(self, arch):
        """Recurrent caches (RWKV/Mamba state) ride the same megastep
        scan; frozen-row keeps must hold across all K inner steps."""
        api = R.build(arch, smoke=True)
        params = api.init(jax.random.PRNGKey(9))
        lens = [3, 7, 5]
        prompts = [np.asarray(jax.random.randint(
            jax.random.PRNGKey(22 + i), (n,), 0, api.cfg.vocab), np.int32)
            for i, n in enumerate(lens)]
        refs = [np.asarray(reference_decode(
            api, params, jnp.asarray(p)[None], 6, cache_len=32))[0]
            for p in prompts]
        eng = ServeEngine(api, params, EngineConfig(
            max_batch=2, cache_len=32, prefill_chunk=3, megastep=4))
        assert not eng.paged
        rids = [eng.submit(p, 6, arrival_step=2 * i).rid
                for i, p in enumerate(prompts)]
        outs = eng.run(max_steps=200)
        for rid, ref in zip(rids, refs):
            np.testing.assert_array_equal(outs[rid], ref)

    def test_admission_timing_identical_across_widths(self, api, params):
        """run() never megasteps across an admission event: per-request
        admitted/done steps — and the paging traffic they shape — are
        identical at every megastep width."""
        prompts = jax.random.randint(jax.random.PRNGKey(23), (6, 5), 0,
                                     api.cfg.vocab)

        def drive(megastep):
            eng = ServeEngine(api, params, _cfg(max_batch=2,
                                                megastep=megastep))
            rids = [eng.submit(np.asarray(prompts[i]), 8,
                               arrival_step=i).rid for i in range(6)]
            eng.run(max_steps=400)
            timing = [(eng.completed[r].admitted_step,
                       eng.completed[r].done_step) for r in rids]
            st = eng.paging_stats()
            return timing, (st["page_ins"], st["page_outs"]), eng

        t1, p1, e1 = drive(1)
        t8, p8, e8 = drive(8)
        assert t1 == t8
        assert p1 == p8
        assert e8.stats()["host_dispatches"] < e1.stats()["host_dispatches"]
        assert e1.stats()["host_dispatches"] == e1.step_count


class TestMegastepPerfContract:
    def test_one_sync_per_megastep(self, api, params):
        """The whole K-step megastep — compute scan, K paging
        transactions, staged write-through, retirement — performs
        exactly one device->host transfer: the packed readback."""
        eng = ServeEngine(api, params, _cfg(megastep=4))
        prompts = jax.random.randint(jax.random.PRNGKey(24), (3, 6), 0,
                                     api.cfg.vocab)
        for i in range(3):
            eng.submit(np.asarray(prompts[i]), 20)
        eng.megastep(4)      # compile everything outside the guard
        syncs = []
        orig = eng._readback

        def guarded(packed):
            syncs.append(np.asarray(packed).shape)
            with jax.transfer_guard("allow"):
                return orig(packed)

        eng._readback = guarded
        for _ in range(3):
            n = len(syncs)
            with jax.transfer_guard_device_to_host("disallow"):
                report = eng.megastep(4)
            assert len(syncs) == n + 1          # exactly the readback
            assert report["steps"] == 4
        # the readback is the packed (B, 3+K) completion array
        assert all(s == (eng.cfg.max_batch, 3 + 4) for s in syncs)

    def test_program_cached_per_width_and_shared(self, api, params):
        """One compile per (ModelAPI, config, K) cell; a second engine
        sharing the cell reuses the program."""
        eng = ServeEngine(api, params, _cfg(megastep=4))
        eng.submit(np.ones(5, np.int32), 8)
        eng.run(max_steps=100)
        fn4 = eng._mega_fn(4)
        size = fn4._cache_size()
        assert size >= 1
        eng2 = ServeEngine(api, params, _cfg(megastep=4))
        assert eng2._mega_fn(4) is fn4
        eng2.submit(np.ones(5, np.int32), 8)
        eng2.run(max_steps=100)
        assert fn4._cache_size() == size      # zero retraces
        # the K=1 cell is distinct but shared the same way
        assert eng._step_fn is _fused_megastep_program(
            api, eng.cfg.prefill_chunk, 1, eng.cfg.block_tokens)

    def test_run_reports_dispatch_tax(self, api, params):
        """run() at megastep=8 pays far fewer host dispatches than
        steps, and stats() exposes both."""
        eng = ServeEngine(api, params, _cfg(megastep=8))
        eng.submit(np.ones(5, np.int32), 16)
        eng.run(max_steps=200)
        st = eng.stats()
        assert set(st) == {"steps", "host_dispatches", "megasteps",
                           "host_blocked", "faults", "snapshot"}
        assert st["host_dispatches"] <= -(-st["steps"] // 2)
        assert st["host_dispatches"] == st["megasteps"]  # always live here
        # depth-1 blocks on every boundary's readback — the bubble count
        # the pipelined dispatcher exists to shrink
        assert st["host_blocked"] == st["megasteps"]
        # the stats ride along in paging_stats for reporting
        assert eng.paging_stats()["host_dispatches"] == \
            st["host_dispatches"]


class TestTenantServiceCompletion:
    def test_ops_target_completion_varies_with_pattern(self, api, params):
        """Service-driven completion (n_ops): ops queue behind the
        per-direction duplex budget, so unidirectional patterns drain at
        half the balanced rate and each pattern's latency is a real
        measurement, not a shared schedule constant. ``completion_in``
        is a never-late bound (full-rate assumption), so the adaptive
        megastep can trust it."""
        def drive(pattern):
            eng = ServeEngine(api, params, EngineConfig(
                max_batch=2, cache_len=64, block_tokens=4, hbm_blocks=10,
                pool_blocks=128, prefill_chunk=2, max_queue=16,
                megastep=4))
            kv = eng.add_tenant(KVStoreTenant(
                n_slots=4, ops_per_step=2, store_blocks=24))
            kv.preload(24)
            req = kv.submit(pattern, n_steps=96, n_ops=24)
            predicted = kv.completion_in(req)
            eng.run(max_steps=2000)
            done = kv.completed[req.rid]
            assert done.work.ops_done >= 24
            return done.done_step - done.arrival_step, predicted

        lats = {}
        for pattern in ("sequential", "pipelined", "gaussian",
                        "read_heavy"):
            lats[pattern], predicted = drive(pattern)
            # the full-rate bound never predicts later than reality
            assert predicted - 1 <= lats[pattern], pattern
        # direction-capped service: the one-sided pattern pays the
        # turnaround penalty relative to balanced mixes
        assert lats["read_heavy"] > lats["gaussian"], lats
        assert len(set(lats.values())) > 1, lats

    def test_legacy_schedule_mode_unthrottled(self, api, params):
        """Without n_ops, the open-loop contract is unchanged: the
        stream runs its whole schedule, one row per engine step."""
        eng = ServeEngine(api, params, EngineConfig(
            max_batch=2, cache_len=64, block_tokens=4, hbm_blocks=10,
            pool_blocks=128, prefill_chunk=2, max_queue=16))
        kv = eng.add_tenant(KVStoreTenant(
            n_slots=2, ops_per_step=2, store_blocks=16))
        kv.preload(16)
        req = kv.submit("gaussian", n_steps=20)
        eng.run(max_steps=200)
        done = kv.completed[req.rid]
        assert done.done_step - done.arrival_step == 20 - 1


class TestStagedDuplexKernel:
    def test_staged_variant_matches_reference(self, rng):
        in_q = jnp.asarray(rng.integers(-127, 128, (6, 8, 16)), jnp.int8)
        in_scale = jnp.asarray(
            rng.uniform(0.01, 0.2, (6, 8, 1)).astype(np.float32))
        out_x = jnp.asarray(
            rng.standard_normal((6, 8, 16)).astype(np.float32),
            jnp.bfloat16)
        a = kernel_ops.duplex_kv_stream(in_q, in_scale, out_x)
        b = kernel_ops.duplex_kv_stream(in_q, in_scale, out_x,
                                        stage_blocks=2)
        g = kernel_ref.duplex_kv_stream(in_q, in_scale, out_x)
        for x, y, z in zip(a, b, g):
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))
            np.testing.assert_allclose(np.asarray(y, np.float32),
                                       np.asarray(z, np.float32),
                                       atol=1e-2)

    def test_staged_variant_rejects_ragged_streams(self, rng):
        in_q = jnp.zeros((3, 4, 8), jnp.int8)
        in_scale = jnp.ones((3, 4, 1), jnp.float32)
        out_x = jnp.zeros((3, 4, 8), jnp.bfloat16)
        with pytest.raises(ValueError, match="multiple"):
            kernel_ops.duplex_kv_stream(in_q, in_scale, out_x,
                                        stage_blocks=2)


class TestFeedbackFold:
    """core.policies megastep aggregation: fold == aggregated update."""

    def _random_feedbacks(self, rng, n_slots, k):
        return [policies_lib.Feedback(
            moved_read=jnp.asarray(
                rng.uniform(0, 100, n_slots).astype(np.float32)),
            moved_write=jnp.asarray(
                rng.uniform(0, 100, n_slots).astype(np.float32)),
            utilization=jnp.float32(rng.uniform(0, 1)))
            for _ in range(k)]

    @pytest.mark.parametrize("name", ["cfs", "ddr_batching", "hinted"])
    def test_fold_equals_eager_updates(self, name, rng):
        policy = policies_lib.get_policy(name)
        params = policies_lib.PolicyParams()
        for k in (1, 3, 5):
            fbs = self._random_feedbacks(rng, 6, k)
            eager = policy.init(params, 6)
            for fb in fbs:
                eager = policy.update(params, eager, fb)
            folded = policies_lib.fold_feedback(
                policy, params, policy.init(params, 6),
                policies_lib.stack_feedbacks(fbs))
            for a, b in zip(jax.tree.leaves(eager),
                            jax.tree.leaves(folded)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-6, atol=1e-6)

    def test_property_fold_matches_for_all_policies(self, rng):
        hyp = pytest.importorskip("hypothesis")
        st = pytest.importorskip("hypothesis.strategies")
        params = policies_lib.PolicyParams()

        @hyp.given(
            name=st.sampled_from(["cfs", "ddr_batching", "hinted"]),
            k=st.integers(min_value=1, max_value=6),
            seed=st.integers(min_value=0, max_value=2 ** 16),
        )
        @hyp.settings(deadline=None, max_examples=25)
        def check(name, k, seed):
            r = np.random.default_rng(seed)
            policy = policies_lib.get_policy(name)
            fbs = self._random_feedbacks(r, 5, k)
            eager = policy.init(params, 5)
            for fb in fbs:
                eager = policy.update(params, eager, fb)
            folded = policies_lib.fold_feedback(
                policy, params, policy.init(params, 5),
                policies_lib.stack_feedbacks(fbs))
            for a, b in zip(jax.tree.leaves(eager),
                            jax.tree.leaves(folded)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-6)

        check()

    def test_seed_read_fraction_survives_megastep_fold(self, rng):
        """A hint-seeded per-slot rf forecast must not drift through a
        megastep's folded updates, and the post-fold schedule must be
        identical to the per-step path's."""
        policy = policies_lib.get_policy("hinted")
        params = policies_lib.PolicyParams()
        state = policy.init(params, 4)
        state = policies_lib.seed_read_fraction(state, 2, 0.87)
        fbs = self._random_feedbacks(rng, 4, 4)
        folded = policies_lib.fold_feedback(
            policy, params, state, policies_lib.stack_feedbacks(fbs))
        eager = state
        for fb in fbs:
            eager = policy.update(params, eager, fb)
        assert float(folded.ewma_rf[2]) == pytest.approx(0.87)
        z = np.zeros((4,), np.float32)
        obs = policies_lib.Obs(
            step=jnp.int32(4),
            backlog_read=jnp.asarray(z + 10.0),
            backlog_write=jnp.asarray(z + 5.0),
            arrival_read=jnp.asarray(z), arrival_write=jnp.asarray(z),
            head_read=jnp.asarray(z), head_write=jnp.asarray(z),
            prev_weights=jnp.asarray(z), prev_util=jnp.float32(0.0),
            opt_r=jnp.float32(0.55), duplex=jnp.asarray(True),
            hint_rf=jnp.asarray(z + 0.5),
            hint_priority=jnp.asarray(z + 1.0),
            hint_opt_in=jnp.ones((4,), bool))
        _, w_fold = policy.schedule(params, folded, obs)
        _, w_eager = policy.schedule(params, eager, obs)
        np.testing.assert_allclose(np.asarray(w_fold),
                                   np.asarray(w_eager), rtol=1e-6)
