"""ServeEngine continuous batching: staggered arrivals decode exactly like
a static batch, paging batches into one kernel call per step, and the
policy-driven queue orders admission."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hints import HintTree, MemoryHint
from repro.models import registry as R
from repro.serve import EngineConfig, ServeEngine, reference_decode
from repro.serve import kv_pool as kv_pool_mod
from repro.serve.queue import Request, RequestQueue


@pytest.fixture(scope="module")
def api():
    return R.build("smollm-135m", smoke=True)


@pytest.fixture(scope="module")
def params(api):
    return api.init(jax.random.PRNGKey(0))


def _cfg(**kw):
    base = dict(max_batch=3, cache_len=64, block_tokens=4, hbm_blocks=6,
                prefill_chunk=3, max_queue=8)
    base.update(kw)
    return EngineConfig(**base)


class TestContinuousBatching:
    def test_staggered_matches_static_reference(self, api, params):
        """Acceptance: requests arriving mid-stream generate token-for-token
        what the same prompts produce in a static reference batch."""
        prompts = jax.random.randint(jax.random.PRNGKey(1), (5, 6), 0,
                                     api.cfg.vocab)
        ref = np.asarray(reference_decode(api, params, prompts, 10,
                                          cache_len=64))
        eng = ServeEngine(api, params, _cfg())
        rids = [eng.submit(np.asarray(prompts[i]), 10,
                           arrival_step=2 * i).rid
                for i in range(5)]
        outs = eng.run(max_steps=300)
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(outs[rid], ref[i])
        # requests really did arrive and complete mid-stream
        done = [eng.completed[r].done_step for r in rids]
        adm = [eng.completed[r].admitted_step for r in rids]
        assert len(set(done)) > 1 and len(set(adm)) > 1
        assert eng.paging_stats()["page_ins"] > 0

    def test_slot_reuse_after_completion(self, api, params):
        """More requests than slots: retired slots are recycled and the
        recycled slot's stale cache never leaks into new requests."""
        prompts = jax.random.randint(jax.random.PRNGKey(2), (6, 5), 0,
                                     api.cfg.vocab)
        ref = np.asarray(reference_decode(api, params, prompts, 8,
                                          cache_len=64))
        eng = ServeEngine(api, params, _cfg(max_batch=2))
        rids = [eng.submit(np.asarray(prompts[i]), 8).rid
                for i in range(6)]
        outs = eng.run(max_steps=400)
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(outs[rid], ref[i])

    def test_recurrent_state_reset_on_slot_reuse(self):
        """Non-attention caches (RWKV recurrent state) must also be wiped
        when a slot is recycled — paging is gated off but continuous
        batching still has to decode exactly."""
        api = R.build("rwkv6-7b", smoke=True)
        params = api.init(jax.random.PRNGKey(7))
        prompts = jax.random.randint(jax.random.PRNGKey(8), (4, 5), 0,
                                     api.cfg.vocab)
        ref = np.asarray(reference_decode(api, params, prompts, 6,
                                          cache_len=32))
        eng = ServeEngine(api, params, EngineConfig(max_batch=2,
                                                    cache_len=32))
        assert not eng.paged
        rids = [eng.submit(np.asarray(prompts[i]), 6).rid for i in range(4)]
        outs = eng.run(max_steps=200)
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(outs[rid], ref[i])

    @pytest.mark.parametrize("arch", ["rwkv6-7b", "zamba2-7b"])
    def test_recurrent_staggered_arrivals_exact(self, arch):
        """Regression: prefill-only micro-steps (prefill_chunk > 1) must
        not advance frozen DECODE rows' recurrent state (RWKV wkv/shifts,
        hybrid Mamba state) with dummy tokens. Staggered arrivals and
        unequal prompt lengths desynchronize the batch so decoding rows
        coexist with chunk-prefilling rows."""
        api = R.build(arch, smoke=True)
        params = api.init(jax.random.PRNGKey(9))
        lens = [3, 7, 5]
        prompts = [np.asarray(jax.random.randint(
            jax.random.PRNGKey(10 + i), (n,), 0, api.cfg.vocab), np.int32)
            for i, n in enumerate(lens)]
        refs = [np.asarray(reference_decode(
            api, params, jnp.asarray(p)[None], 6, cache_len=32))[0]
            for p in prompts]
        eng = ServeEngine(api, params, EngineConfig(
            max_batch=2, cache_len=32, prefill_chunk=3))
        assert not eng.paged
        rids = [eng.submit(p, 6, arrival_step=2 * i).rid
                for i, p in enumerate(prompts)]
        outs = eng.run(max_steps=200)
        for rid, ref in zip(rids, refs):
            np.testing.assert_array_equal(outs[rid], ref)

    def test_arrival_step_respected(self, api, params):
        eng = ServeEngine(api, params, _cfg())
        late = eng.submit(np.ones(4, np.int32), 2, arrival_step=5)
        early = eng.submit(np.ones(4, np.int32), 2, arrival_step=0)
        eng.run(max_steps=100)
        assert eng.completed[early.rid].admitted_step == 0
        assert eng.completed[late.rid].admitted_step >= 5

    def test_rejects_oversized_request(self, api, params):
        eng = ServeEngine(api, params, _cfg(cache_len=16))
        with pytest.raises(ValueError, match="cache positions"):
            eng.submit(np.ones(10, np.int32), 10)


class TestBatchedPaging:
    def test_one_kernel_invocation_per_engine_step(self, api, params,
                                                   monkeypatch):
        """Acceptance: one duplex_kv_stream call per engine step, no matter
        how many requests page."""
        calls = []
        real = kv_pool_mod.kernel_ops.duplex_kv_stream

        def counting(*a, **kw):
            calls.append(a[0].shape)
            return real(*a, **kw)

        monkeypatch.setattr(kv_pool_mod.kernel_ops, "duplex_kv_stream",
                            counting)
        eng = ServeEngine(api, params, _cfg(max_batch=3, hbm_blocks=5))
        prompts = jax.random.randint(jax.random.PRNGKey(3), (3, 6), 0,
                                     api.cfg.vocab)
        for i in range(3):
            eng.submit(np.asarray(prompts[i]), 12)
        per_step = []
        while eng.pending():
            before = len(calls)
            eng.step()
            per_step.append(len(calls) - before)
        assert max(per_step) == 1                 # never more than one
        assert sum(per_step) == eng.pool.stats["kernel_calls"]
        # multi-request traffic really was batched into single calls:
        # some kernel invocation carried more than one block.
        assert max(n for (n, _, _) in calls) > 1
        assert eng.paging_stats()["page_outs"] > 0

    def test_write_through_matches_dense_cache(self, api, params):
        """Pool blocks hold the *real* KV: every resident block of an
        active request matches the dense cache within int8 round-trip
        tolerance (catches stale/dummy entries in freshly filled blocks)."""
        from repro.serve.engine import _extract_blocks
        eng = ServeEngine(api, params, _cfg(max_batch=2, hbm_blocks=8))
        prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 6), 0,
                                     api.cfg.vocab)
        for i in range(2):
            eng.submit(np.asarray(prompts[i]), 14)
        for _ in range(10):
            eng.step()
        bt = eng.cfg.block_tokens
        slot_of = np.asarray(eng.pool.slot_of)
        checked = 0
        for r in eng.active():
            for bi, blk in enumerate(r.blocks):
                if slot_of[blk] < 0:
                    continue
                dense = np.asarray(_extract_blocks(
                    eng.cache, [r.slot], [bi * bt], bt)[0], np.float32)
                pooled = np.asarray(eng.pool.hbm[slot_of[blk]], np.float32)
                amax = np.abs(dense).max()
                assert np.abs(pooled - dense).max() <= amax / 127.0 + 0.05
                checked += 1
        assert checked > 0

    def test_paging_disabled_still_serves(self, api, params):
        eng = ServeEngine(api, params, _cfg(paging=False))
        prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 5), 0,
                                     api.cfg.vocab)
        ref = np.asarray(reference_decode(api, params, prompts, 6,
                                          cache_len=64))
        rids = [eng.submit(np.asarray(prompts[i]), 6).rid for i in range(2)]
        outs = eng.run(max_steps=100)
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(outs[rid], ref[i])
        assert eng.paging_stats() == {"paged": False}

    def test_duplex_speedup_reported(self, api, params):
        eng = ServeEngine(api, params, _cfg(max_batch=3, hbm_blocks=5))
        prompts = jax.random.randint(jax.random.PRNGKey(5), (5, 6), 0,
                                     api.cfg.vocab)
        for i in range(5):
            eng.submit(np.asarray(prompts[i]), 12, arrival_step=i)
        eng.run(max_steps=300)
        st = eng.paging_stats()
        assert st["duplex_speedup"] > 1.0
        assert st["page_ins"] > 0 and st["page_outs"] > 0


class TestAdmissionPolicy:
    def test_priority_hint_orders_admission(self):
        hints = HintTree()
        hints.set("/serve/vip", MemoryHint(priority=4.0))
        hints.set("/serve/batch", MemoryHint(priority=0.25))
        q = RequestQueue(capacity=8, policy="hinted", hints=hints)
        low = q.submit(Request(prompt=np.ones(8, np.int32),
                               max_new_tokens=4, hint_path="/serve/batch"))
        vip = q.submit(Request(prompt=np.ones(8, np.int32),
                               max_new_tokens=4, hint_path="/serve/vip"))
        first = q.dispatch(now=0, n_free=1)
        assert first == [vip]
        second = q.dispatch(now=0, n_free=1)
        assert second == [low]

    def test_dispatch_respects_free_slots_and_arrivals(self):
        q = RequestQueue(capacity=8)
        reqs = [q.submit(Request(prompt=np.ones(4, np.int32),
                                 max_new_tokens=2, arrival_step=s))
                for s in (0, 0, 3)]
        got = q.dispatch(now=0, n_free=2)
        assert set(r.rid for r in got) == {reqs[0].rid, reqs[1].rid}
        assert q.dispatch(now=0, n_free=4) == []      # last not arrived yet
        assert q.dispatch(now=3, n_free=4) == [reqs[2]]

    def test_fifo_tiebreak_survives_slot_recycling(self):
        """Equal-weight requests admit in submit order even after a
        waiting-room slot is recycled by an earlier admission (threshold
        is stateless, so identical requests really do tie)."""
        q = RequestQueue(capacity=2, policy="threshold")
        a = q.submit(Request(prompt=np.ones(4, np.int32), max_new_tokens=2))
        b = q.submit(Request(prompt=np.ones(4, np.int32), max_new_tokens=2))
        assert q.dispatch(now=0, n_free=1) == [a]
        c = q.submit(Request(prompt=np.ones(4, np.int32),
                             max_new_tokens=2))   # lands in a's old slot
        assert q.dispatch(now=0, n_free=1) == [b]
        assert q.dispatch(now=0, n_free=1) == [c]

    def test_recycled_slot_inherits_no_policy_state(self):
        """A request recycling a waiting slot must not inherit the
        previous occupant's accumulated vruntime (hinted is stateful, so
        a stale clock would push the recycler behind later arrivals)."""
        q = RequestQueue(capacity=2, policy="hinted")

        def mk():
            return Request(prompt=np.ones(8, np.int32), max_new_tokens=4)

        a = q.submit(mk())
        assert q.dispatch(now=0, n_free=1) == [a]   # charges slot 0
        c = q.submit(mk())                          # recycles slot 0
        d = q.submit(mk())                          # fresh slot 1
        assert q.dispatch(now=0, n_free=1) == [c]
        assert q.dispatch(now=0, n_free=1) == [d]

    def test_queue_capacity_enforced(self):
        q = RequestQueue(capacity=1)
        q.submit(Request(prompt=np.ones(2, np.int32), max_new_tokens=1))
        with pytest.raises(RuntimeError, match="full"):
            q.submit(Request(prompt=np.ones(2, np.int32), max_new_tokens=1))
