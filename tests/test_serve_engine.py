"""ServeEngine continuous batching: staggered arrivals decode exactly like
a static batch, paging batches into one kernel call per step, and the
policy-driven queue orders admission."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hints import HintTree, MemoryHint
from repro.models import registry as R
from repro.serve import (EngineConfig, KVStoreTenant, ServeEngine,
                         VectorSearchTenant, reference_decode)
from repro.serve import workloads as workloads_mod
from repro.serve.queue import Request, RequestQueue


@pytest.fixture(scope="module")
def api():
    return R.build("smollm-135m", smoke=True)


@pytest.fixture(scope="module")
def params(api):
    return api.init(jax.random.PRNGKey(0))


def _cfg(**kw):
    base = dict(max_batch=3, cache_len=64, block_tokens=4, hbm_blocks=6,
                prefill_chunk=3, max_queue=8)
    base.update(kw)
    return EngineConfig(**base)


class TestContinuousBatching:
    def test_staggered_matches_static_reference(self, api, params):
        """Acceptance: requests arriving mid-stream generate token-for-token
        what the same prompts produce in a static reference batch."""
        prompts = jax.random.randint(jax.random.PRNGKey(1), (5, 6), 0,
                                     api.cfg.vocab)
        ref = np.asarray(reference_decode(api, params, prompts, 10,
                                          cache_len=64))
        eng = ServeEngine(api, params, _cfg())
        rids = [eng.submit(np.asarray(prompts[i]), 10,
                           arrival_step=2 * i).rid
                for i in range(5)]
        outs = eng.run(max_steps=300)
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(outs[rid], ref[i])
        # requests really did arrive and complete mid-stream
        done = [eng.completed[r].done_step for r in rids]
        adm = [eng.completed[r].admitted_step for r in rids]
        assert len(set(done)) > 1 and len(set(adm)) > 1
        assert eng.paging_stats()["page_ins"] > 0

    def test_slot_reuse_after_completion(self, api, params):
        """More requests than slots: retired slots are recycled and the
        recycled slot's stale cache never leaks into new requests."""
        prompts = jax.random.randint(jax.random.PRNGKey(2), (6, 5), 0,
                                     api.cfg.vocab)
        ref = np.asarray(reference_decode(api, params, prompts, 8,
                                          cache_len=64))
        eng = ServeEngine(api, params, _cfg(max_batch=2))
        rids = [eng.submit(np.asarray(prompts[i]), 8).rid
                for i in range(6)]
        outs = eng.run(max_steps=400)
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(outs[rid], ref[i])

    def test_recurrent_state_reset_on_slot_reuse(self):
        """Non-attention caches (RWKV recurrent state) must also be wiped
        when a slot is recycled — paging is gated off but continuous
        batching still has to decode exactly."""
        api = R.build("rwkv6-7b", smoke=True)
        params = api.init(jax.random.PRNGKey(7))
        prompts = jax.random.randint(jax.random.PRNGKey(8), (4, 5), 0,
                                     api.cfg.vocab)
        ref = np.asarray(reference_decode(api, params, prompts, 6,
                                          cache_len=32))
        eng = ServeEngine(api, params, EngineConfig(max_batch=2,
                                                    cache_len=32))
        assert not eng.paged
        rids = [eng.submit(np.asarray(prompts[i]), 6).rid for i in range(4)]
        outs = eng.run(max_steps=200)
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(outs[rid], ref[i])

    @pytest.mark.parametrize("arch", ["rwkv6-7b", "zamba2-7b"])
    def test_recurrent_staggered_arrivals_exact(self, arch):
        """Regression: prefill-only micro-steps (prefill_chunk > 1) must
        not advance frozen DECODE rows' recurrent state (RWKV wkv/shifts,
        hybrid Mamba state) with dummy tokens. Staggered arrivals and
        unequal prompt lengths desynchronize the batch so decoding rows
        coexist with chunk-prefilling rows."""
        api = R.build(arch, smoke=True)
        params = api.init(jax.random.PRNGKey(9))
        lens = [3, 7, 5]
        prompts = [np.asarray(jax.random.randint(
            jax.random.PRNGKey(10 + i), (n,), 0, api.cfg.vocab), np.int32)
            for i, n in enumerate(lens)]
        refs = [np.asarray(reference_decode(
            api, params, jnp.asarray(p)[None], 6, cache_len=32))[0]
            for p in prompts]
        eng = ServeEngine(api, params, EngineConfig(
            max_batch=2, cache_len=32, prefill_chunk=3))
        assert not eng.paged
        rids = [eng.submit(p, 6, arrival_step=2 * i).rid
                for i, p in enumerate(prompts)]
        outs = eng.run(max_steps=200)
        for rid, ref in zip(rids, refs):
            np.testing.assert_array_equal(outs[rid], ref)

    def test_arrival_step_respected(self, api, params):
        eng = ServeEngine(api, params, _cfg())
        late = eng.submit(np.ones(4, np.int32), 2, arrival_step=5)
        early = eng.submit(np.ones(4, np.int32), 2, arrival_step=0)
        eng.run(max_steps=100)
        assert eng.completed[early.rid].admitted_step == 0
        assert eng.completed[late.rid].admitted_step >= 5

    def test_rejects_oversized_request(self, api, params):
        eng = ServeEngine(api, params, _cfg(cache_len=16))
        with pytest.raises(ValueError, match="cache positions"):
            eng.submit(np.ones(10, np.int32), 10)

    def test_rejects_write_through_overflow_at_submit(self, api, params):
        """A prompt that would fill more KV blocks in one prefill step
        than the pool's HBM holds is rejected at submit time, not by a
        RuntimeError mid-step in _page_kv."""
        eng = ServeEngine(api, params, _cfg(
            block_tokens=4, prefill_chunk=16, hbm_blocks=2, cache_len=64))
        with pytest.raises(ValueError, match="HBM"):
            eng.submit(np.ones(20, np.int32), 8)
        # a short prompt that cannot overflow is still accepted
        eng.submit(np.ones(4, np.int32), 2)

    def test_joint_prefill_demand_throttles_at_admission(self, api,
                                                         params):
        """Two prompts that each pass the submit-time guard but would
        jointly overflow the write-through in one step are staggered by
        the admission budget instead of raising mid-step — and still
        decode exactly."""
        prompts = jax.random.randint(jax.random.PRNGKey(13), (2, 8), 0,
                                     api.cfg.vocab)
        ref = np.asarray(reference_decode(api, params, prompts, 6,
                                          cache_len=64))
        eng = ServeEngine(api, params, EngineConfig(
            max_batch=2, cache_len=64, block_tokens=4, hbm_blocks=3,
            prefill_chunk=8))
        rids = [eng.submit(np.asarray(prompts[i]), 6).rid
                for i in range(2)]
        outs = eng.run(max_steps=200)
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(outs[rid], ref[i])
        # they really were staggered, not co-admitted
        adm = [eng.completed[r].admitted_step for r in rids]
        assert len(set(adm)) == 2

    def test_run_error_names_pending_rids(self, api, params):
        eng = ServeEngine(api, params, _cfg())
        r = eng.submit(np.ones(4, np.int32), 8)
        with pytest.raises(RuntimeError, match=rf"rids \[{r.rid}\]"):
            eng.run(max_steps=1)


class TestBatchedPaging:
    def test_one_kernel_invocation_per_engine_step(self, api, params,
                                                   kernel_call_counter):
        """Acceptance: at most one stream-kernel invocation per engine
        step — the fused duplex kernel when both directions carry blocks,
        a single-direction half otherwise — no matter how many requests
        page."""
        calls = kernel_call_counter
        eng = ServeEngine(api, params, _cfg(max_batch=3, hbm_blocks=5))
        prompts = jax.random.randint(jax.random.PRNGKey(3), (3, 6), 0,
                                     api.cfg.vocab)
        for i in range(3):
            eng.submit(np.asarray(prompts[i]), 12)
        per_step = []
        while eng.pending():
            before = len(calls)
            eng.step()
            per_step.append(len(calls) - before)
        assert max(per_step) == 1                 # never more than one
        assert sum(per_step) == eng.pool.stats["kernel_calls"]
        # multi-request traffic really was batched into single calls:
        # some kernel invocation carried more than one block.
        assert max(n for _, n in calls) > 1
        assert eng.paging_stats()["page_outs"] > 0

    def test_write_through_matches_dense_cache(self, api, params):
        """Pool blocks hold the *real* KV: every resident block of an
        active request matches the dense cache within int8 round-trip
        tolerance (catches stale/dummy entries in freshly filled blocks)."""
        from repro.serve.engine import _extract_blocks
        eng = ServeEngine(api, params, _cfg(max_batch=2, hbm_blocks=8))
        prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 6), 0,
                                     api.cfg.vocab)
        for i in range(2):
            eng.submit(np.asarray(prompts[i]), 14)
        for _ in range(10):
            eng.step()
        bt = eng.cfg.block_tokens
        slot_of = np.asarray(eng.pool.slot_of)
        checked = 0
        for r in eng.active():
            for bi, blk in enumerate(r.blocks):
                if slot_of[blk] < 0:
                    continue
                dense = np.asarray(_extract_blocks(
                    eng.cache, [r.slot], [bi * bt], bt)[0], np.float32)
                pooled = np.asarray(eng.pool.hbm[slot_of[blk]], np.float32)
                amax = np.abs(dense).max()
                assert np.abs(pooled - dense).max() <= amax / 127.0 + 0.05
                checked += 1
        assert checked > 0

    def test_paging_disabled_still_serves(self, api, params):
        eng = ServeEngine(api, params, _cfg(paging=False))
        prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 5), 0,
                                     api.cfg.vocab)
        ref = np.asarray(reference_decode(api, params, prompts, 6,
                                          cache_len=64))
        rids = [eng.submit(np.asarray(prompts[i]), 6).rid for i in range(2)]
        outs = eng.run(max_steps=100)
        for i, rid in enumerate(rids):
            np.testing.assert_array_equal(outs[rid], ref[i])
        st = eng.paging_stats()
        assert st["paged"] is False
        assert "page_ins" not in st          # no pool, no paging counters
        assert st["host_dispatches"] == eng.step_count  # megastep=1

    def test_duplex_speedup_reported(self, api, params):
        eng = ServeEngine(api, params, _cfg(max_batch=3, hbm_blocks=5))
        prompts = jax.random.randint(jax.random.PRNGKey(5), (5, 6), 0,
                                     api.cfg.vocab)
        for i in range(5):
            eng.submit(np.asarray(prompts[i]), 12, arrival_step=i)
        eng.run(max_steps=300)
        st = eng.paging_stats()
        assert st["duplex_speedup"] > 1.0
        assert st["page_ins"] > 0 and st["page_outs"] > 0


class TestPerfContract:
    """The fused-step perf contract: one XLA program per engine step,
    compiled exactly once per (arch, config), with at most one
    device->host sync per step (the completion readback)."""

    def test_fused_step_compiles_once(self, api, params):
        """The fused step traces decode_step exactly once across a full
        staggered run — and a second engine sharing the (ModelAPI,
        config) cell reuses the compiled program (no retrace)."""
        traces = []
        counting_api = api._replace(
            decode_step=lambda *a: (traces.append(1)
                                    or api.decode_step(*a)))

        def drive():
            eng = ServeEngine(counting_api, params, _cfg())
            prompts = jax.random.randint(jax.random.PRNGKey(11), (4, 5),
                                         0, api.cfg.vocab)
            for i in range(4):
                eng.submit(np.asarray(prompts[i]), 8, arrival_step=2 * i)
            eng.run(max_steps=300)
            return eng

        eng = drive()
        first = len(traces)
        assert first >= 1          # traced (scan body traces once)
        # the jitted step program compiled exactly once for this cell
        assert eng._step_fn._cache_size() == 1
        eng2 = drive()
        assert len(traces) == first        # shared program, zero retraces
        assert eng2._step_fn is eng._step_fn
        assert eng2._step_fn._cache_size() == 1

    def test_single_host_sync_per_step(self, api, params):
        """The whole engine step — fused micro-steps, paging planning,
        write-through, retirement — performs exactly one device->host
        sync: the packed completion readback (asserted with
        jax.transfer_guard)."""
        eng = ServeEngine(api, params, _cfg())
        prompts = jax.random.randint(jax.random.PRNGKey(12), (3, 6), 0,
                                     api.cfg.vocab)
        for i in range(3):
            eng.submit(np.asarray(prompts[i]), 10)
        eng.step()          # compile everything outside the guard
        syncs = []
        orig_readback = eng._readback

        def guarded_readback(packed):
            syncs.append(1)
            with jax.transfer_guard("allow"):
                return orig_readback(packed)

        eng._readback = guarded_readback
        for _ in range(3):
            n = len(syncs)
            with jax.transfer_guard_device_to_host("disallow"):
                report = eng.step()
            assert len(syncs) == n + 1      # exactly the readback
            assert report["advanced"] > 0

    def test_readback_is_single_packed_array(self, api, params):
        """The completion readback materializes exactly one host array
        per step."""
        eng = ServeEngine(api, params, _cfg())
        eng.submit(np.ones(4, np.int32), 4)
        seen = []
        orig = eng._readback
        eng._readback = lambda packed: (seen.append(packed),
                                        orig(packed))[1]
        eng.run(max_steps=100)
        # every executed step had live rows -> exactly one readback each,
        # always the same packed (B, 4) int32 array
        assert len(seen) == eng.step_count
        assert all(p.shape == (eng.cfg.max_batch, 4) for p in seen)

    def test_refuses_non_fusable_api(self, api, params):
        bad = api._replace(fused_decode=False)
        with pytest.raises(ValueError, match="fused_decode"):
            ServeEngine(bad, params, _cfg())


class TestMixedTenantPerfContract:
    """The fused-step perf contract extended to mixed-tenant steps: one
    jitted program per (tenant-mix, config) cell — a second engine with
    the same mix retraces nothing — and the LLM completion readback stays
    the step's only device->host sync even while KV-store and
    vector-search tenants page and compute every step."""

    def _mixed_cfg(self):
        return EngineConfig(max_batch=2, cache_len=64, block_tokens=4,
                            hbm_blocks=14, pool_blocks=96,
                            prefill_chunk=2, max_queue=16)

    def _drive(self, counting_api, params):
        eng = ServeEngine(counting_api, params, self._mixed_cfg())
        kv = eng.add_tenant(KVStoreTenant(n_slots=2, ops_per_step=2,
                                          store_blocks=16))
        vec = eng.add_tenant(VectorSearchTenant(
            n_slots=1, visits_per_step=2, data_blocks=8))
        prompts = jax.random.randint(jax.random.PRNGKey(31), (2, 5), 0,
                                     counting_api.cfg.vocab)
        for i in range(2):
            eng.submit(np.asarray(prompts[i]), 8, arrival_step=2 * i)
        kv.submit("sequential", n_steps=24)
        kv.submit("sequential", n_steps=24)
        vec.submit(n_steps=20)
        eng.run(max_steps=300)
        assert kv.ops_done > 0 and vec.queries_done > 0
        return eng

    def test_mixed_tenant_compiles_once(self, api, params):
        """decode_step traces once for the whole mixed run, and the
        tenant programs' jit caches do not grow when a second engine
        drives the same (tenant-mix, config) cell."""
        traces = []
        counting_api = api._replace(
            decode_step=lambda *a: (traces.append(1)
                                    or api.decode_step(*a)))
        eng = self._drive(counting_api, params)
        first = len(traces)
        assert first >= 1
        assert eng._step_fn._cache_size() == 1
        tenant_programs = (workloads_mod._synth_blocks,
                           workloads_mod._gather_checksum,
                           workloads_mod._visit_blocks,
                           workloads_mod._pack_result)
        sizes = [p._cache_size() for p in tenant_programs]
        assert all(s >= 1 for s in sizes)
        eng2 = self._drive(counting_api, params)
        assert len(traces) == first            # zero decode retraces
        assert eng2._step_fn is eng._step_fn
        assert [p._cache_size() for p in tenant_programs] == sizes

    def test_mixed_tenant_single_host_sync_per_step(self, api, params):
        """Steady-state mixed-tenant steps perform exactly one
        device->host transfer — the LLM packed completion readback.
        Tenant paging, value writes, gathers, and the distance kernel
        all stay on device (device-resident accumulators sync only at
        ``result()``)."""
        eng = ServeEngine(api, params, self._mixed_cfg())
        kv = eng.add_tenant(KVStoreTenant(n_slots=2, ops_per_step=2,
                                          store_blocks=16))
        vec = eng.add_tenant(VectorSearchTenant(
            n_slots=1, visits_per_step=2, data_blocks=8))
        prompts = jax.random.randint(jax.random.PRNGKey(32), (2, 6), 0,
                                     api.cfg.vocab)
        for i in range(2):
            eng.submit(np.asarray(prompts[i]), 20)
        kv.submit("sequential", n_steps=40)
        kv.submit("sequential", n_steps=40)
        vec.submit(n_steps=40)
        for _ in range(4):
            eng.step()      # compile + admit everything outside the guard
        syncs = []
        orig_readback = eng._readback

        def guarded_readback(packed):
            syncs.append(1)
            with jax.transfer_guard("allow"):
                return orig_readback(packed)

        eng._readback = guarded_readback
        for _ in range(4):
            before_ops = kv.ops_done
            n_syncs = len(syncs)
            with jax.transfer_guard_device_to_host("disallow"):
                eng.step()
            assert len(syncs) == n_syncs + 1   # exactly the readback
            assert kv.ops_done > before_ops    # tenants really worked


class TestAdmissionPolicy:
    def test_priority_hint_orders_admission(self):
        hints = HintTree()
        hints.set("/serve/vip", MemoryHint(priority=4.0))
        hints.set("/serve/batch", MemoryHint(priority=0.25))
        q = RequestQueue(capacity=8, policy="hinted", hints=hints)
        low = q.submit(Request(prompt=np.ones(8, np.int32),
                               max_new_tokens=4, hint_path="/serve/batch"))
        vip = q.submit(Request(prompt=np.ones(8, np.int32),
                               max_new_tokens=4, hint_path="/serve/vip"))
        first = q.dispatch(now=0, n_free=1)
        assert first == [vip]
        second = q.dispatch(now=0, n_free=1)
        assert second == [low]

    def test_dispatch_respects_free_slots_and_arrivals(self):
        q = RequestQueue(capacity=8)
        reqs = [q.submit(Request(prompt=np.ones(4, np.int32),
                                 max_new_tokens=2, arrival_step=s))
                for s in (0, 0, 3)]
        got = q.dispatch(now=0, n_free=2)
        assert set(r.rid for r in got) == {reqs[0].rid, reqs[1].rid}
        assert q.dispatch(now=0, n_free=4) == []      # last not arrived yet
        assert q.dispatch(now=3, n_free=4) == [reqs[2]]

    def test_fifo_tiebreak_survives_slot_recycling(self):
        """Equal-weight requests admit in submit order even after a
        waiting-room slot is recycled by an earlier admission (threshold
        is stateless, so identical requests really do tie)."""
        q = RequestQueue(capacity=2, policy="threshold")
        a = q.submit(Request(prompt=np.ones(4, np.int32), max_new_tokens=2))
        b = q.submit(Request(prompt=np.ones(4, np.int32), max_new_tokens=2))
        assert q.dispatch(now=0, n_free=1) == [a]
        c = q.submit(Request(prompt=np.ones(4, np.int32),
                             max_new_tokens=2))   # lands in a's old slot
        assert q.dispatch(now=0, n_free=1) == [b]
        assert q.dispatch(now=0, n_free=1) == [c]

    def test_recycled_slot_inherits_no_policy_state(self):
        """A request recycling a waiting slot must not inherit the
        previous occupant's accumulated vruntime (hinted is stateful, so
        a stale clock would push the recycler behind later arrivals)."""
        q = RequestQueue(capacity=2, policy="hinted")

        def mk():
            return Request(prompt=np.ones(8, np.int32), max_new_tokens=4)

        a = q.submit(mk())
        assert q.dispatch(now=0, n_free=1) == [a]   # charges slot 0
        c = q.submit(mk())                          # recycles slot 0
        d = q.submit(mk())                          # fresh slot 1
        assert q.dispatch(now=0, n_free=1) == [c]
        assert q.dispatch(now=0, n_free=1) == [d]

    def test_queue_capacity_enforced(self):
        q = RequestQueue(capacity=1)
        q.submit(Request(prompt=np.ones(2, np.int32), max_new_tokens=1))
        with pytest.raises(RuntimeError, match="full"):
            q.submit(Request(prompt=np.ones(2, np.int32), max_new_tokens=1))
